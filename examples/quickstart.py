"""Quickstart: the Roomy programming model in 5 minutes.

Walks the paper's API on both tiers:
  Tier J (device arrays)  — repro.core
  Tier D (real disk)      — repro.core.disk

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import array as RA
from repro.core import constructs as C
from repro.core import hashtable as HT
from repro.core import rlist as RL
from repro.core.disk import DiskList


def tier_j_tour():
    print("== Tier J (device) ==")
    # RoomyList: multiset with streaming dedup / difference
    rl = RL.from_rows(jnp.array([[3], [1], [3], [7], [1]], jnp.uint32),
                      capacity=16)
    print("size:", int(rl.count))
    rl = RL.remove_dupes(rl)
    print("after removeDupes:", sorted(RL.to_numpy(rl)[:, 0].tolist()))

    # paper's reduce example: sum of squares
    s = RL.reduce(rl, lambda r: (r[0] * r[0]).astype(jnp.uint32),
                  lambda a, b: a + b, jnp.uint32(0))
    print("sum of squares:", int(s))

    # RoomyArray: delayed updates + sync (scatter-gather)
    ra = RA.make(jnp.zeros(8, jnp.int32), queue_capacity=16,
                 payload_dtype=jnp.int32)
    ra, _ = RA.update(ra, jnp.array([2, 2, 5], jnp.int32),
                      jnp.array([10, 20, 7], jnp.int32))
    ra = RA.sync(ra, combine=lambda a, b: a + b,
                 apply=lambda old, agg: old + agg)
    print("array after sync:", np.asarray(ra.data).tolist())

    # chain reduction (paper §3): a[i] += a[i-1], old values throughout
    ra2 = RA.make(jnp.arange(6, dtype=jnp.int32), queue_capacity=8,
                  payload_dtype=jnp.int32)
    ra2 = C.chain_reduce(ra2, lambda old, prev: old + prev)
    print("chain reduction:", np.asarray(ra2.data).tolist())

    # RoomyHashTable: delayed inserts merged at sync
    ht = HT.make(capacity=16, key_width=1, queue_capacity=8,
                 val_dtype=jnp.int32)
    ht, _ = HT.insert(ht, jnp.array([[5], [9], [5]], jnp.uint32),
                      jnp.array([1, 2, 3], jnp.int32))
    ht, _ = HT.sync(ht, combine=lambda a, b: a + b,
                    apply=lambda o, g, p: jnp.where(p, o + g, g))
    vals, found = HT.lookup(ht, jnp.array([[5], [9], [0]], jnp.uint32))
    print("hashtable lookups:", np.asarray(vals).tolist(),
          np.asarray(found).tolist())


def tier_d_tour():
    print("\n== Tier D (real disk, streaming) ==")
    with tempfile.TemporaryDirectory() as wd:
        dl = DiskList(wd, width=1, chunk_rows=1024)   # tiny chunks
        rng = np.random.default_rng(0)
        dl.add(rng.integers(0, 5000, (20_000, 1)).astype(np.uint32))
        print("disk list size:", dl.size())
        dl.remove_dupes(run_rows=2048)                # external merge sort
        print("unique elements:", dl.size())
        total = dl.reduce(lambda c: int(c[:, 0].astype(np.int64).sum()),
                          lambda a, b: a + b, 0)
        print("streaming reduce (sum):", total)
        dl.destroy()


if __name__ == "__main__":
    tier_j_tour()
    tier_d_tour()
