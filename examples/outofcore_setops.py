"""Out-of-core set algebra (paper §3 'Set Operations'), genuinely on disk.

Builds two multisets far larger than the configured RAM budget (chunk
size), converts them to sets, and computes union / difference /
intersection with the paper's exact recipes — all passes streaming, RAM
held at O(chunk). Verifies against an in-RAM oracle at the end.

  PYTHONPATH=src python examples/outofcore_setops.py --n 2000000 \
      --chunk-rows 65536
"""
import argparse
import tempfile
import time

import numpy as np

from repro.core.disk import DiskList


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=500_000)
    ap.add_argument("--chunk-rows", type=int, default=1 << 14)
    ap.add_argument("--verify", action="store_true", default=True)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    with tempfile.TemporaryDirectory() as wd:
        A = DiskList(wd, width=1, chunk_rows=args.chunk_rows)
        B = DiskList(wd, width=1, chunk_rows=args.chunk_rows)
        a_vals = rng.integers(0, args.n, args.n).astype(np.uint32)
        b_vals = rng.integers(args.n // 2, 3 * args.n // 2,
                              args.n).astype(np.uint32)
        A.add(a_vals[:, None]); B.add(b_vals[:, None])
        ram_budget_mb = args.chunk_rows * 4 / 1e6
        print(f"|A|={A.size()} |B|={B.size()} rows on disk; "
              f"RAM budget ≈ {ram_budget_mb:.2f} MB/chunk")

        t0 = time.perf_counter()
        A.remove_dupes(run_rows=args.chunk_rows)      # A := set(A)
        B.remove_dupes(run_rows=args.chunk_rows)
        print(f"as sets: |A|={A.size()} |B|={B.size()} "
              f"({time.perf_counter()-t0:.2f}s)")

        # paper recipe: A∩B = (A+B) − (A−B) − (B−A)
        t0 = time.perf_counter()
        AB = DiskList(wd, width=1, chunk_rows=args.chunk_rows)
        AB.add_all(A); AB.add_all(B)
        AB.remove_dupes(run_rows=args.chunk_rows)     # union
        AmB = DiskList(wd, width=1, chunk_rows=args.chunk_rows)
        AmB.add_all(A); AmB.remove_all(B)             # A − B
        BmA = DiskList(wd, width=1, chunk_rows=args.chunk_rows)
        BmA.add_all(B); BmA.remove_all(A)             # B − A
        I = DiskList(wd, width=1, chunk_rows=args.chunk_rows)
        I.add_all(AB); I.remove_all(AmB); I.remove_all(BmA)
        dt = time.perf_counter() - t0
        print(f"|A∪B|={AB.size()} |A−B|={AmB.size()} |B−A|={BmA.size()} "
              f"|A∩B|={I.size()}  ({dt:.2f}s, "
              f"{(A.size()+B.size())/dt:.0f} elt/s)")

        if args.verify:
            sa, sb = set(a_vals.tolist()), set(b_vals.tolist())
            assert AB.size() == len(sa | sb)
            assert AmB.size() == len(sa - sb)
            assert BmA.size() == len(sb - sa)
            assert I.size() == len(sa & sb)
            got = set(I.read_all()[:, 0].tolist())
            assert got == (sa & sb)
            print("verified against in-RAM oracle ✓")


if __name__ == "__main__":
    main()
