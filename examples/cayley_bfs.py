"""Cayley-graph BFS: S_n under adjacent transpositions (bubble-sort graph).

A second symbolic-algebra application of the Roomy BFS engine (the paper's
home domain). Ground truth is exact: the distance of a permutation from
the identity equals its inversion count, so

  level sizes  == Mahonian numbers T(n, k)   (# permutations, k inversions)
  diameter     == n(n-1)/2

The script enumerates the graph with the Tier-J (device) or Tier-D (real
disk) engine and checks both facts against a DP oracle.

  PYTHONPATH=src python examples/cayley_bfs.py --n 6 --tier disk
"""
import argparse
import math
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import constructs as C
from repro.core.disk import breadth_first_search as disk_bfs
from repro.core.disk import trace


def mahonian(n):
    """T(n, k) for k = 0..n(n-1)/2 via the classic DP."""
    t = [1]
    for m in range(2, n + 1):
        new = [0] * (len(t) + m - 1)
        for k, v in enumerate(t):
            for j in range(m):
                new[k + j] += v
        t = new
    return t


class GenNextNp:
    """Adjacent-transposition chunk expander — a picklable class (not a
    closure) so the sharded disk BFS (``--shards N``, spawn workers) can
    ship it to worker processes."""

    def __init__(self, n: int):
        self.n = n

    def __call__(self, chunk):
        n = self.n
        codes = chunk[:, 0]
        perms = np.stack([(codes >> (4 * i)) & 0xF for i in range(n)],
                         axis=1).astype(np.int64)
        outs = []
        for i in range(n - 1):                    # swap positions i, i+1
            sw = perms.copy()
            sw[:, [i, i + 1]] = sw[:, [i + 1, i]]
            code = np.zeros(chunk.shape[0], np.uint32)
            for j in range(n):
                code |= sw[:, j].astype(np.uint32) << np.uint32(4 * j)
            outs.append(code)
        return np.concatenate(outs)[:, None]


def gen_next_np(n):
    return GenNextNp(n)


def gen_next_jnp(n):
    def gen(row):
        code = row[0]
        perm = jnp.stack([(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                          for i in range(n)]).astype(jnp.int32)
        outs = []
        for i in range(n - 1):
            idx = list(range(n))
            idx[i], idx[i + 1] = idx[i + 1], idx[i]
            sw = perm[jnp.array(idx)]
            acc = jnp.uint32(0)
            for j in range(n):
                acc = acc | (sw[j].astype(jnp.uint32) << jnp.uint32(4 * j))
            outs.append(acc)
        return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=6)
    ap.add_argument("--tier", choices=("j", "disk"), default="disk")
    ap.add_argument("--shards", type=int, default=1,
                    help="distribute the disk-tier search over N shard "
                         "workers")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a structured JSONL trace of the run to "
                         "PATH and print the per-level report at exit "
                         "(docs/observability.md)")
    args = ap.parse_args()
    n = args.n
    assert 3 <= n <= 12
    assert args.shards == 1 or args.tier == "disk", \
        "--shards is a disk-tier (Tier D) feature"
    total = math.factorial(n)
    start = np.uint32(sum(i << (4 * i) for i in range(n)))
    want = mahonian(n)
    print(f"S_{n} bubble-sort Cayley graph: {total} vertices, "
          f"diameter should be {n*(n-1)//2}")

    if args.trace:
        # Start BEFORE the search builds its runtime: spawn workers read
        # $ROOMY_TRACE at startup to buffer shard-tagged spans.
        trace.start(args.trace, meta={"example": "cayley_bfs", "n": n,
                                      "tier": args.tier,
                                      "nshards": args.shards})

    if args.tier == "j":
        res = C.breadth_first_search(
            np.array([[start]], np.uint32), gen_next_jnp(n), fanout=n - 1,
            width=1, all_capacity=total + 8, level_capacity=total + 8)
        sizes = res.level_sizes
    else:
        with tempfile.TemporaryDirectory() as wd:
            sizes, all_lst = disk_bfs(wd, np.array([[start]], np.uint32),
                                      gen_next_np(n), width=1,
                                      chunk_rows=1 << 13,
                                      nshards=args.shards)
            all_lst.destroy()

    if args.trace:
        trace.report(trace.stop())

    print("level sizes:", sizes)
    assert sizes == want, f"Mahonian mismatch!\n got {sizes}\nwant {want}"
    assert len(sizes) - 1 == n * (n - 1) // 2
    print(f"✓ level sizes == Mahonian numbers T({n},k); "
          f"diameter {len(sizes)-1} == n(n-1)/2")


if __name__ == "__main__":
    main()
