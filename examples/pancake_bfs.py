"""Pancake sorting via Roomy BFS — the paper's flagship application.

Enumerates the pancake graph (all n! stacks, edges = prefix reversals) and
reports the flip-distance histogram + diameter, on either tier:

  PYTHONPATH=src python examples/pancake_bfs.py --n 7 --tier disk
  PYTHONPATH=src python examples/pancake_bfs.py --n 8 --tier j

Known diameters (OEIS A058986): 4→4 5→5 6→7 7→8 8→9 9→10 10→11.
The disk tier keeps RAM at O(chunk) regardless of n — crank --n up and
watch the working directory instead of your memory.
"""
import argparse
import math
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import constructs as C
from repro.core.disk import (CheckpointConfig, ClusterConfig,
                             RecoveryConfig)
from repro.core.disk import breadth_first_search as disk_bfs
from repro.core.disk import extsort, faults, trace


def start_code(n):
    return np.uint32(sum(i << (4 * i) for i in range(n)))


class GenNextNp:
    """All-prefix-flips chunk expander on the 4-bit packed encoding.

    A class (not a closure) so instances PICKLE: the sharded disk BFS
    (``--shards N``, spawn-mode ShardRuntime workers) ships the generator
    to worker processes."""

    def __init__(self, n: int):
        self.n = n

    def __call__(self, chunk):
        n = self.n
        codes = chunk[:, 0]
        perms = np.stack([(codes >> (4 * i)) & 0xF for i in range(n)],
                         axis=1).astype(np.int64)
        outs = []
        for k in range(2, n + 1):
            flipped = np.concatenate([perms[:, :k][:, ::-1], perms[:, k:]],
                                     axis=1)
            code = np.zeros(chunk.shape[0], np.uint32)
            for i in range(n):
                code |= flipped[:, i].astype(np.uint32) << np.uint32(4 * i)
            outs.append(code)
        return np.concatenate(outs)[:, None]


def gen_next_np(n):
    return GenNextNp(n)


def gen_next_jnp(n):
    def gen(row):
        code = row[0]
        perm = jnp.stack([(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                          for i in range(n)]).astype(jnp.int32)
        outs = []
        for k in range(2, n + 1):
            flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
            acc = jnp.uint32(0)
            for i in range(n):
                acc = acc | (flipped[i].astype(jnp.uint32)
                             << jnp.uint32(4 * i))
            outs.append(acc)
        return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=7)
    ap.add_argument("--tier", choices=("j", "disk"), default="disk")
    ap.add_argument("--chunk-rows", type=int, default=1 << 14)
    ap.add_argument("--shards", type=int, default=1,
                    help="run the disk tier distributed over N shard "
                         "workers (multiprocess ShardRuntime)")
    ap.add_argument("--shard-mode", choices=("spawn", "inline"),
                    default="spawn")
    ap.add_argument("--transport", choices=("fs", "tcp", "loopback"),
                    default="fs",
                    help="bucket wire between shards (docs/transports.md): "
                         "shared filesystem, TCP sockets (no shared "
                         "scratch), or the in-process loopback store "
                         "(inline mode only)")
    ap.add_argument("--exchange", choices=("barrier", "pipelined"),
                    default=None,
                    help="exchange discipline: classic two-phase barrier "
                         "(default) or overlapped produce/apply")
    ap.add_argument("--check", action="store_true",
                    help="assert the level counts match a fresh "
                         "single-shard uninterrupted run (sharded and/or "
                         "resumed searches alike)")
    ap.add_argument("--compress", action="store_true",
                    help="store sorted runs delta+varint compressed "
                         "(disk tier; docs/compression.md) — same level "
                         "counts and sort budgets, fewer stored bytes; "
                         "composes with --check, which always runs its "
                         "reference search UNCOMPRESSED")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="persist mid-search checkpoints to DIR "
                         "(disk tier; see docs/checkpointing.md)")
    ap.add_argument("--checkpoint-every", type=int, default=1, metavar="N",
                    help="checkpoint every N completed levels")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--checkpoint-dir instead of starting over")
    ap.add_argument("--stop-after", type=int, default=None, metavar="LEVEL",
                    help="stop ('kill') the search after LEVEL completed "
                         "levels — pair with --checkpoint-dir, then rerun "
                         "with --resume")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run under a seeded fault storm (ROOMY_FAULTS, "
                         "docs/fault-tolerance.md): torn appends + "
                         "transient I/O flakes, plus a real worker kill "
                         "when --shards > 1 — the search must self-heal "
                         "to the exact fault-free level counts")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a structured JSONL trace of the run to "
                         "PATH and print the per-level report at exit "
                         "(docs/observability.md); composes with --shards "
                         "and --chaos")
    args = ap.parse_args()
    n = args.n
    assert 3 <= n <= 12, "4-bit packing supports n <= 12"
    assert args.shards == 1 or args.tier == "disk", \
        "--shards is a disk-tier (Tier D) feature"
    assert (args.checkpoint_dir is not None
            or not (args.resume or args.stop_after is not None)), \
        "--resume/--stop-after need --checkpoint-dir"
    assert args.checkpoint_dir is None or args.tier == "disk", \
        "checkpointing is a disk-tier (Tier D) feature"
    assert not (args.check and args.stop_after is not None), \
        "--check compares COMPLETE searches; drop --stop-after"
    assert args.chaos is None or args.tier == "disk", \
        "--chaos is a disk-tier (Tier D) feature"
    assert not args.compress or args.tier == "disk", \
        "--compress is a disk-tier (Tier D) feature"
    chaos = args.chaos is not None
    if chaos and not os.environ.get(faults.ENV_VAR):
        # An explicit ROOMY_FAULTS (the CI chaos matrix) wins; --chaos
        # alone gets the default seeded storm.  The env var is how spawn
        # workers inherit the plan.
        os.environ[faults.ENV_VAR] = faults.default_chaos_spec(
            args.chaos, args.shards)
    total = math.factorial(n)
    print(f"pancake n={n}: {total} states, tier={args.tier}"
          + (f", shards={args.shards}" if args.shards > 1 else ""))

    if args.trace:
        # Start BEFORE the search builds its runtime: spawn workers read
        # $ROOMY_TRACE at startup to buffer shard-tagged spans.
        trace.start(args.trace, meta={"example": "pancake_bfs", "n": n,
                                      "tier": args.tier,
                                      "nshards": args.shards})

    max_levels = args.stop_after if args.stop_after is not None else 10_000
    t0 = time.perf_counter()
    if args.tier == "j":
        res = C.breadth_first_search(
            np.array([[start_code(n)]], np.uint32), gen_next_jnp(n),
            fanout=n - 1, width=1, all_capacity=total + 8,
            level_capacity=total + 8)
        sizes = res.level_sizes
    else:
        with tempfile.TemporaryDirectory() as wd:
            ckdir = args.checkpoint_dir
            if chaos and ckdir is None:
                # Surviving a kill needs checkpoints: --chaos turns them
                # on in the scratch dir when none were requested.
                ckdir = os.path.join(wd, "chaos_ck")
            sizes, all_lst = disk_bfs(
                wd, np.array([[start_code(n)]], np.uint32), gen_next_np(n),
                width=1, chunk_rows=args.chunk_rows, max_levels=max_levels,
                compress=args.compress,
                cluster=ClusterConfig(nshards=args.shards,
                                      mode=args.shard_mode,
                                      transport=args.transport,
                                      exchange=args.exchange),
                checkpoint=CheckpointConfig(
                    dir=ckdir, every=args.checkpoint_every,
                    resume=args.resume),
                recovery=RecoveryConfig(max_recoveries=8 if chaos else 0))
            all_lst.destroy()
    dt = time.perf_counter() - t0

    if chaos:
        print(f"chaos: ROOMY_FAULTS={os.environ[faults.ENV_VAR]!r}")
        print(f"chaos: io_retries={extsort.STATS['io_retries']} "
              f"io_giveups={extsort.STATS['io_giveups']} "
              f"recoveries={extsort.STATS['recoveries']} "
              f"replayed_levels={extsort.STATS['replayed_levels']}")
        # The storm stays out of everything after the search — in
        # particular the --check reference run must be fault-free.
        os.environ.pop(faults.ENV_VAR, None)
        faults.uninstall()

    if args.trace:
        # Close before the --check reference run: the trace describes the
        # (possibly sharded, possibly chaos-ridden) run above, nothing else.
        trace.report(trace.stop())

    if args.stop_after is not None and sum(sizes) < total:
        print("level sizes so far:", sizes)
        print(f"stopped after level {len(sizes) - 1} (checkpoint kept in "
              f"{args.checkpoint_dir}) — rerun with --resume to finish")
        return
    assert sum(sizes) == total, "did not enumerate the full graph!"
    print("level sizes:", sizes)
    print(f"diameter (max flips to sort): {len(sizes) - 1}")
    print(f"{total / dt:.0f} states/s ({dt:.2f}s)")

    if args.check:
        with tempfile.TemporaryDirectory() as wd:
            want, all_lst = disk_bfs(
                wd, np.array([[start_code(n)]], np.uint32), gen_next_np(n),
                width=1, chunk_rows=args.chunk_rows)
            all_lst.destroy()
        assert sizes == want, (sizes, want)
        print("check: matches an uninterrupted single-shard run exactly")


if __name__ == "__main__":
    main()
