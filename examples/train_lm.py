"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

The ``100m`` preset is a llama-style dense model (d=640, 10L, ff=2560,
vocab 50k ⇒ ~97M params) trained on the deterministic synthetic stream
with the full production stack: WSD schedule, AdamW, global-norm clip,
microbatching, periodic async checkpoints, straggler watchdog — the same
code path the dry-run lowers at pod scale.

  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
  PYTHONPATH=src python examples/train_lm.py --preset 25m --steps 60   # CI

(One CPU core ⇒ the 100m/300-step run takes tens of minutes; the loss
curve prints every 10 steps so progress is visible.)
"""
import argparse

from repro.models.config import ModelConfig
from repro.runtime import TrainSettings, train

PRESETS = {
    # 10L·d768·ff3072 + 8k vocab = 100.7M params
    "100m": ModelConfig(
        name="demo-100m", family="dense", n_layers=10, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=8_192, mlp_act="silu", mlp_gated=True,
        tie_embeddings=True, dtype="float32", kernels="ref"),
    "25m": ModelConfig(
        name="demo-25m", family="dense", n_layers=6, d_model=448,
        n_heads=7, n_kv_heads=7, head_dim=64, d_ff=1792,
        vocab_size=8_192, mlp_act="silu", mlp_gated=True,
        tie_embeddings=True, dtype="float32", kernels="ref"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=tuple(PRESETS), default="100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M  "
          f"steps={args.steps}  tokens/step={args.batch * args.seq}")
    settings = TrainSettings(
        batch=args.batch, seq=args.seq, steps=args.steps, lr=args.lr,
        warmup_steps=max(10, args.steps // 20), schedule="wsd",
        num_microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir, log_every=10)
    out = train(cfg, settings)
    print(f"loss: {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"over {len(out['losses'])} steps")


if __name__ == "__main__":
    main()
