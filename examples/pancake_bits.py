"""Pancake numbers via the implicit bit-array BFS — the paper's Table 1.

The paper's flagship result (pancake-number upper bounds) is NOT computed
with sorted lists: each permutation's Myrvold–Ruskey rank indexes a
RoomyArray of 2-bit elements, and a BFS level is two streaming passes over
that array — no sorting, no duplicate elimination.  This example reproduces
the Table-1-style level counts (flip-distance histogram) with that engine:

  PYTHONPATH=src python examples/pancake_bits.py --n 9 --tier disk
  PYTHONPATH=src python examples/pancake_bits.py --n 7 --tier j
  PYTHONPATH=src python examples/pancake_bits.py --n 7 --check   # vs sorted

``--check`` cross-validates against the sorted-list engine
(disk.breadth_first_search), which is limited to n ≤ 8 by its single-word
4-bit state packing — the bit-array engine has no such limit (rank rows
are 1 uint32 word up to n=12, 2 words to n=20), which is exactly the
ROADMAP "scale past 8!" item.  Known diameters (OEIS A058986):
4→4 5→5 6→7 7→8 8→9 9→10 10→11.
"""
import argparse
import math
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import constructs as C
from repro.core import obs
from repro.core import ranking as R
from repro.core.disk import (CheckpointConfig, ClusterConfig,
                             RecoveryConfig)
from repro.core.disk import breadth_first_search as disk_bfs
from repro.core.disk import extsort, faults, trace
from repro.core.disk import implicit_bfs as disk_implicit_bfs


class NeighborsNp:
    """(m,) int64 ranks → (m, n-1) int64 neighbor ranks (all prefix flips).

    A class (not a closure) so instances PICKLE — the sharded implicit
    BFS (``--shards N``) ships the generator to spawn-mode workers."""

    def __init__(self, n: int):
        self.n = n

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        n = self.n
        perms = R.unrank_np(n, np.asarray(idx, np.uint64))
        outs = []
        for k in range(2, n + 1):
            flipped = np.concatenate([perms[:, :k][:, ::-1], perms[:, k:]],
                                     axis=1)
            outs.append(R.rank_np(flipped).astype(np.int64))
        return np.stack(outs, axis=1)


def neighbors_np(n: int):
    return NeighborsNp(n)


def neighbor_jnp(n: int):
    """Rank → (n-1,) int32 neighbor ranks, single-word (Tier J fits RAM,
    so n ≤ 12 always holds there)."""
    assert n <= R.MAX_N_1WORD

    def nf(i):
        perm = R.unrank_jnp(n, i.reshape(1, 1).astype(jnp.uint32))[0]
        outs = []
        for k in range(2, n + 1):
            flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
            outs.append(R.rank_jnp(flipped[None, :], width=1)[0, 0])
        return jnp.stack(outs).astype(jnp.int32)
    return nf


def sorted_list_levels(n: int, chunk_rows: int = 1 << 14):
    """Oracle: the sorted-list engine on the 4-bit row encoding (n ≤ 8).

    The generator comes from the sibling sorted-engine example — one copy
    of the packed-pancake expansion, so the oracle can't drift from it.
    """
    assert n <= 8, "single-word 4-bit row packing stops at 8!"
    from pancake_bfs import gen_next_np, start_code
    with tempfile.TemporaryDirectory() as wd:
        sizes, all_obj = disk_bfs(wd, np.array([[start_code(n)]], np.uint32),
                                  gen_next_np(n), width=1,
                                  chunk_rows=chunk_rows)
        all_obj.destroy()
    return sizes


def _ram_distances(n: int, start_rank: int, total: int) -> np.ndarray:
    """In-RAM reference BFS distance table (n <= 8 — 8! ranks fit easily);
    the independent oracle the --publish --check sampling compares against."""
    gen = neighbors_np(n)
    dist = np.full(total, -1, np.int64)
    dist[start_rank] = 0
    frontier = np.asarray([start_rank], np.int64)
    d = 0
    while frontier.size:
        nb = np.unique(gen(frontier).reshape(-1))
        nb = nb[dist[nb] < 0]
        d += 1
        dist[nb] = d
        frontier = nb
    return dist


def run(n: int, tier: str, chunk_elems: int, check: bool, shards: int = 1,
        shard_mode: str = "spawn", checkpoint_dir=None,
        checkpoint_every: int = 1, resume: bool = False, stop_after=None,
        chaos=None, trace_path=None, transport: str = "fs", exchange=None,
        publish_dir=None, compress: bool = False):
    total = math.factorial(n)
    start_rank = int(R.rank_np(np.arange(n)[None, :])[0])
    print(f"pancake n={n}: {total} states, tier={tier}, "
          f"bit array = {-(-total // 4)} bytes packed"
          + (f", shards={shards}" if shards > 1 else ""))
    if chaos is not None and not os.environ.get(faults.ENV_VAR):
        # An explicit ROOMY_FAULTS (the CI chaos matrix) wins; --chaos
        # alone gets the default seeded storm.  The env var is how spawn
        # workers inherit the plan.
        os.environ[faults.ENV_VAR] = faults.default_chaos_spec(chaos, shards)
    if trace_path:
        # Start BEFORE the search builds its runtime: spawn workers read
        # $ROOMY_TRACE at startup to buffer shard-tagged spans.
        trace.start(trace_path, meta={"example": "pancake_bits", "n": n,
                                      "tier": tier, "nshards": shards})

    max_levels = stop_after if stop_after is not None else 10_000
    sco = obs.Scope()        # this search's counter window (no global reset)
    t0 = time.perf_counter()
    if tier == "j":
        sizes, jbits = C.implicit_bfs(total, [start_rank], neighbor_jnp(n))
        # HBM analogue of the disk byte counters: the packed array is read
        # and written once per level (mark pass + rotate pass).
        io_line = (f"bytes/level: {2 * jbits.data.nbytes} "
                   f"(packed array, read+written)")
    else:
        with tempfile.TemporaryDirectory() as wd:
            if chaos is not None and checkpoint_dir is None:
                # Surviving a kill needs checkpoints: --chaos turns them
                # on in the scratch dir when none were requested.
                checkpoint_dir = os.path.join(wd, "chaos_ck")
            sizes, bits = disk_implicit_bfs(
                wd, total, [start_rank], neighbors_np(n),
                chunk_elems=chunk_elems, max_levels=max_levels,
                compress=compress,
                cluster=ClusterConfig(nshards=shards, mode=shard_mode,
                                      transport=transport,
                                      exchange=exchange),
                checkpoint=CheckpointConfig(dir=checkpoint_dir,
                                            every=checkpoint_every,
                                            resume=resume),
                recovery=RecoveryConfig(
                    max_recoveries=8 if chaos is not None else 0))
            if stop_after is None:
                hist = bits.count_values()
                assert hist[0] == 0, "unreached states — graph not connected?"
            bits.destroy()
        # Complete in every mode: single-process books directly, inline
        # workers share this process's registry, and spawn workers' deltas
        # are folded back at each level barrier (ShardRuntime.collect_obs).
        bs = sco.delta()["bits"]
        io_line = (f"bytes touched: {bs['bytes_read']} read "
                   f"{bs['bytes_written']} written"
                   + (" (incl. folded worker totals)" if shards > 1 else ""))
    dt = time.perf_counter() - t0

    if chaos is not None:
        print(f"chaos: ROOMY_FAULTS={os.environ[faults.ENV_VAR]!r}")
        print(f"chaos: io_retries={extsort.STATS['io_retries']} "
              f"io_giveups={extsort.STATS['io_giveups']} "
              f"recoveries={extsort.STATS['recoveries']} "
              f"replayed_levels={extsort.STATS['replayed_levels']}")
        # The storm stays out of everything after the search — in
        # particular the --check reference runs must be fault-free.
        os.environ.pop(faults.ENV_VAR, None)
        faults.uninstall()

    if trace_path:
        # Close before the --check reference runs: the trace describes the
        # (possibly sharded, possibly chaos-ridden) run above, nothing else.
        trace.report(trace.stop())

    if stop_after is not None and sum(sizes) < total:
        print("level sizes so far:", sizes)
        print(f"stopped after level {len(sizes) - 1} (checkpoint kept in "
              f"{checkpoint_dir}) — rerun with --resume to finish")
        return
    assert sum(sizes) == total, "did not enumerate the full graph!"
    print(f"{'flips':>6} {'states':>12} {'cumulative':>12}")
    cum = 0
    for lev, c in enumerate(sizes):
        cum += c
        print(f"{lev:>6} {c:>12} {cum:>12}")
    print(f"diameter (pancake number): {len(sizes) - 1}")
    print(f"{total / dt:.0f} states/s ({dt:.2f}s)  {io_line}")

    if publish_dir is not None:
        from repro.core.disk.oracle import publish_oracle
        # ~16 chunks regardless of n so an LRU budget below the artifact
        # size actually exercises eviction (chunk size must divide by 4).
        ce = max(4, (-(-total // 16) + 3) // 4 * 4)
        meta = publish_oracle(
            publish_dir, total, [start_rank], neighbors_np(n),
            level_sizes=sizes, chunk_elems=ce, compress=compress,
            codec={"space": "pancake", "n": n,
                   "ranking": "myrvold-ruskey"})
        print(f"published distance oracle v{meta['version']:06d} -> "
              f"{publish_dir} ({meta['n_chunks']} chunks, "
              f"diameter {len(meta['level_sizes']) - 1}; "
              "serve it with repro.core.disk.DistanceOracle — "
              "docs/serving.md)")

    if check:
        if shards > 1:
            # Sharded vs single-shard: the distribution must not move a
            # single state across levels.
            with tempfile.TemporaryDirectory() as wd:
                want, bits = disk_implicit_bfs(
                    wd, total, [start_rank], neighbors_np(n),
                    chunk_elems=chunk_elems)
                bits.destroy()
            assert sizes == want, (sizes, want)
            print("check: matches the single-shard level counts exactly")
        else:
            want = sorted_list_levels(n)
            assert sizes == want, (sizes, want)
            print("check: matches sorted-list BFS level counts exactly")
        if publish_dir is not None:
            from repro.core.disk.oracle import DistanceOracle
            gen = neighbors_np(n)
            with DistanceOracle(publish_dir, cache_bytes=1 << 16,
                                gen_neighbors=gen) as orc:
                assert orc.level_sizes == sizes, \
                    "published histogram drifted from the search's"
                assert n <= 8, "--publish --check reference BFS needs n <= 8"
                ref = _ram_distances(n, start_rank, total)
                hist = np.bincount(ref[ref >= 0]).tolist()
                assert hist == sizes, (hist, sizes)
                if total <= math.factorial(7):
                    sample = np.arange(total, dtype=np.int64)
                else:
                    sample = np.random.default_rng(0).choice(
                        total, 4096, replace=False).astype(np.int64)
                got = orc.lookup(sample)
                assert (got == ref[sample]).all(), \
                    "oracle distances disagree with the reference BFS"
            print(f"check: oracle distances match the reference BFS on "
                  f"{sample.size} sampled ranks (histogram matches the "
                  "engine level sets)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=9)
    ap.add_argument("--tier", choices=("j", "disk"), default="disk")
    ap.add_argument("--chunk-elems", type=int, default=1 << 20)
    ap.add_argument("--shards", type=int, default=1,
                    help="distribute the bit array over N shard workers "
                         "(disk tier only)")
    ap.add_argument("--shard-mode", choices=("spawn", "inline"),
                    default="spawn")
    ap.add_argument("--transport", choices=("fs", "tcp", "loopback"),
                    default="fs",
                    help="bucket wire between shards (docs/transports.md): "
                         "shared filesystem, TCP sockets (no shared "
                         "scratch), or the in-process loopback store "
                         "(inline mode only)")
    ap.add_argument("--exchange", choices=("barrier", "pipelined"),
                    default=None,
                    help="exchange discipline: classic two-phase barrier "
                         "(default) or overlapped produce/apply")
    ap.add_argument("--check", action="store_true",
                    help="cross-validate: vs the sorted-list engine "
                         "(n<=8), or vs an uninterrupted single-shard "
                         "run when --shards > 1 or --resume")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="persist mid-search checkpoints to DIR "
                         "(disk tier; see docs/checkpointing.md)")
    ap.add_argument("--checkpoint-every", type=int, default=1, metavar="N",
                    help="checkpoint every N completed levels")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--checkpoint-dir instead of starting over")
    ap.add_argument("--stop-after", type=int, default=None, metavar="LEVEL",
                    help="stop ('kill') the search after LEVEL completed "
                         "levels — pair with --checkpoint-dir, then rerun "
                         "with --resume")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run under a seeded fault storm (ROOMY_FAULTS, "
                         "docs/fault-tolerance.md): torn appends + "
                         "transient I/O flakes, plus a real worker kill "
                         "when --shards > 1 — the search must self-heal "
                         "to the exact fault-free level counts")
    ap.add_argument("--publish", default=None, metavar="DIR",
                    help="after the search completes, seal it as an "
                         "immutable versioned distance-oracle artifact "
                         "under DIR (docs/serving.md); with --check the "
                         "published oracle's distances are verified "
                         "against an independent reference BFS")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a structured JSONL trace of the run to "
                         "PATH and print the per-level report at exit "
                         "(docs/observability.md); composes with --shards "
                         "and --chaos")
    ap.add_argument("--compress", action="store_true",
                    help="store bit-array chunks run-length encoded and "
                         "seal --publish artifacts as the compressed "
                         "format-2 layout (disk tier; "
                         "docs/compression.md) — same level counts and "
                         "pass budgets, fewer stored bytes; composes "
                         "with --check, whose reference runs stay "
                         "uncompressed")
    args = ap.parse_args()
    assert 3 <= args.n <= R.MAX_N, f"rank encoding supports n <= {R.MAX_N}"
    assert args.shards == 1 or args.tier == "disk", \
        "--shards is a disk-tier (Tier D) feature"
    assert (args.checkpoint_dir is not None
            or not (args.resume or args.stop_after is not None)), \
        "--resume/--stop-after need --checkpoint-dir"
    assert args.checkpoint_dir is None or args.tier == "disk", \
        "checkpointing is a disk-tier (Tier D) feature"
    assert not (args.check and args.stop_after is not None), \
        "--check compares COMPLETE searches; drop --stop-after"
    assert args.chaos is None or args.tier == "disk", \
        "--chaos is a disk-tier (Tier D) feature"
    assert not (args.publish and args.stop_after is not None), \
        "--publish seals COMPLETE searches; drop --stop-after"
    assert not args.compress or args.tier == "disk", \
        "--compress is a disk-tier (Tier D) feature"
    run(args.n, args.tier, args.chunk_elems, args.check, args.shards,
        args.shard_mode, args.checkpoint_dir, args.checkpoint_every,
        args.resume, args.stop_after, args.chaos, args.trace,
        args.transport, args.exchange, args.publish, args.compress)


if __name__ == "__main__":
    main()
