"""Batched serving demo: continuous batching over Roomy paged KV caches.

Eight requests with staggered lengths stream through a 4-slot server; the
scheduler admits waiting requests as slots free up. Works for any
token-input arch:

  PYTHONPATH=src python examples/serve_lm.py --arch minicpm-2b
  PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.runtime import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True).replace(kernels="ref")
    params = init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, max_batch=args.max_batch, max_len=128)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        4 + i % 5).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    outs = server.run(reqs)
    dt = time.perf_counter() - t0
    for rid in sorted(outs):
        print(f"req {rid}: {outs[rid][:10]}{'...' if args.max_new > 10 else ''}")
    total = sum(len(v) for v in outs.values())
    print(f"\n{total} tokens in {dt:.2f}s = {total/dt:.1f} tok/s | "
          f"stats {server.stats}")


if __name__ == "__main__":
    main()
