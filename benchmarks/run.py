"""Benchmark harness — one family per paper construct/claim.

Prints ``name,us_per_call,derived`` CSV (the harness contract). Sections:
  constructs   paper §3 programming constructs on Tier J
  pancake      the paper's flagship BFS app, tier J vs real-disk vs oracle
  disk         Tier-D streaming primitives (external sort, merge, reduce)
  moe          Roomy dispatch vs einsum baseline (8 fake devices)
  lm           per-family train/decode step wall times (smoke configs)
  serve        distance-oracle serving tier: QPS + p50/p99 under
               concurrent closed-loop clients at a starved LRU budget
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=("constructs", "pancake", "bfs",
                                       "disk", "moe", "lm", "serve"))
    ap.add_argument("--pancake-n", type=int, default=7)
    ap.add_argument("--shards", type=int, default=0,
                    help="also benchmark the sharded Tier D runtime with "
                         "N shards (bfs section; 0 = skip)")
    ap.add_argument("--compress", action="store_true",
                    help="also benchmark compressed runs (bfs section; the "
                         "rows report stored bytes/level + raw/stored ratio "
                         "from the codec ledger and surface as unchecked "
                         "NOTEs in benchmarks/compare.py until folded into "
                         "the baseline)")
    ap.add_argument("--json", metavar="PATH",
                    help="also dump results as JSON (the BENCH trajectory "
                         "record: {section: [{name, us_per_call, derived}]})")
    args = ap.parse_args()

    from . import constructs, disk_tier, lm_step, moe_dispatch, pancake

    def bench_bfs_section():
        # Imported lazily: bfs pulls in examples/cayley_bfs.py via a path
        # hack, and an import failure there must not take down the other
        # sections (the try/except below only guards section execution).
        from . import bfs
        return bfs.bench_bfs(args.pancake_n, shards=args.shards,
                             compress=args.compress)

    def bench_serve_section():
        # Lazy for the same examples path hack; its own section keeps the
        # CI gate (--section bfs) and BENCH_baseline.json untouched.
        from . import serve
        return serve.bench_serve(args.pancake_n)

    sections = {
        "constructs": lambda: constructs.bench_constructs(),
        "pancake": lambda: pancake.bench_pancake(args.pancake_n),
        "bfs": bench_bfs_section,
        "disk": lambda: disk_tier.bench_disk(),
        "moe": lambda: moe_dispatch.bench_moe_dispatch(),
        "lm": lambda: lm_step.bench_lm_steps(),
        "serve": bench_serve_section,
    }
    # Schema: sections always maps to a LIST of row dicts (empty on
    # failure); errors live in a separate map so consumers can iterate
    # sections uniformly.
    record = {"timestamp": time.time(), "sections": {}, "errors": {}}
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        try:
            rows = list(fn())
            for row in rows:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                sys.stdout.flush()
            record["sections"][name] = [
                {"name": r[0], "us_per_call": r[1], "derived": r[2]}
                for r in rows]
        except Exception as e:                # a failed section must not
            print(f"{name}_FAILED,0,{e!r}")   # hide the others
            record["sections"][name] = []
            record["errors"][name] = repr(e)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
    return None


if __name__ == "__main__":
    main()
