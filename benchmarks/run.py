"""Benchmark harness — one family per paper construct/claim.

Prints ``name,us_per_call,derived`` CSV (the harness contract). Sections:
  constructs   paper §3 programming constructs on Tier J
  pancake      the paper's flagship BFS app, tier J vs real-disk vs oracle
  disk         Tier-D streaming primitives (external sort, merge, reduce)
  moe          Roomy dispatch vs einsum baseline (8 fake devices)
  lm           per-family train/decode step wall times (smoke configs)
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=("constructs", "pancake", "disk",
                                       "moe", "lm"))
    ap.add_argument("--pancake-n", type=int, default=7)
    args = ap.parse_args()

    from . import constructs, disk_tier, lm_step, moe_dispatch, pancake

    sections = {
        "constructs": lambda: constructs.bench_constructs(),
        "pancake": lambda: pancake.bench_pancake(args.pancake_n),
        "disk": lambda: disk_tier.bench_disk(),
        "moe": lambda: moe_dispatch.bench_moe_dispatch(),
        "lm": lambda: lm_step.bench_lm_steps(),
    }
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                sys.stdout.flush()
        except Exception as e:                # a failed section must not
            print(f"{name}_FAILED,0,{e!r}")   # hide the others
    return None


if __name__ == "__main__":
    main()
