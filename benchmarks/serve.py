"""Distance-oracle serving benchmark: QPS + p50/p99 under concurrent load.

The serving tier's acceptance shape (ISSUE 9 / ROADMAP item 1): publish a
pancake oracle artifact, set the LRU chunk-cache budget WELL below the
artifact size (default 20% — every client batch misses somewhere), and
drive closed-loop client threads issuing batched queries.  Rows report

  queries/s    completed single-rank lookups per wall second, all clients
  p50/p99 us   per-batch latency percentiles from obs.Histogram buckets
               (the percentile() satellite — one histogram per client,
               merged by elementwise addition at the end)
  cache ...    the exact ``oracle`` namespace counters: hit rate and
               eviction traffic at the starved budget

and the bench FAILS (raises → run.py books it in the errors map) if the
exact counters ever show resident cache bytes above the budget — the
cache contract, pinned by accounting rather than sampling.

The ``codes`` row serves raw mod-3 codes (one cache gather per batch);
the ``distance`` row serves exact distances via batched greedy descent
(~diameter gathers per batch); the ``tierJ_gather`` row replays the same
query stream through the kernels/ops.py bitpack_gather2 ref oracle over
the packed words, the device-resident analogue of a fully warm cache.

New rows land in their own ``serve`` section: the CI bench gate compares
section ``bfs`` only, so ``BENCH_baseline.json`` stays byte-identical
(anyone merging a full sweep sees them as unchecked NOTEs per
benchmarks/compare.py).
"""
from __future__ import annotations

import math
import os
import sys
import tempfile
import threading
import time
from typing import List, Tuple

import numpy as np

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))

from repro.core import obs
from repro.core.disk import oracle as ORC

from pancake_bits import neighbors_np


def _publish(tmp: str, n: int) -> Tuple[str, list]:
    import repro.core.ranking as R
    total = math.factorial(n)
    start = int(R.rank_np(np.arange(n)[None, :])[0])
    art = os.path.join(tmp, f"oracle{n}")
    # ~24 chunks so a 20% budget holds only a handful of them.
    ce = max(4, (-(-total // 24) + 3) // 4 * 4)
    meta = ORC.publish_oracle(art, total, [start], neighbors_np(n),
                              chunk_elems=ce,
                              codec={"space": "pancake", "n": n})
    return art, meta


def _closed_loop(query_fn, total: int, clients: int, batches_per_client: int,
                 batch: int) -> Tuple[float, obs.Histogram]:
    """Drive ``clients`` closed-loop threads; returns (wall_s, merged
    per-batch latency histogram in microseconds)."""
    hists = [obs.Histogram() for _ in range(clients)]
    errors: List[BaseException] = []

    def client(ci: int) -> None:
        rng = np.random.default_rng(1000 + ci)
        try:
            for _ in range(batches_per_client):
                ranks = rng.integers(0, total, batch).astype(np.int64)
                t0 = time.perf_counter()
                query_fn(ranks)
                hists[ci].observe((time.perf_counter() - t0) * 1e6)
        except BaseException as e:        # surfaced to the caller: a bench
            errors.append(e)              # thread must never die silently
    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    merged = obs.Histogram()
    for h in hists:
        for b, c in h.buckets.items():
            merged.buckets[b] = merged.buckets.get(b, 0) + c
        merged.count += h.count
        merged.total += h.total
    return wall, merged


def bench_serve(n: int = 7, clients: int = 4, batch: int = 512,
                batches_per_client: int = 40,
                cache_frac: float = 0.20) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    total = math.factorial(n)
    gen = neighbors_np(n)
    with tempfile.TemporaryDirectory() as tmp:
        art, meta = _publish(tmp, n)
        probe = ORC.DistanceOracle(art, cache_bytes=1 << 30)
        art_bytes = probe.artifact_bytes
        probe.close()
        budget = max(1, int(cache_frac * art_bytes))
        assert budget < art_bytes // 4, "budget must stay < 25% of artifact"

        for name, shards in (("serve_codes", 1),
                             ("serve_codes_sh2", 2),
                             ("serve_distance", 1)):
            ORC.reset_stats()
            if shards == 1:
                orc = ORC.DistanceOracle(art, cache_bytes=budget,
                                         gen_neighbors=gen)
            else:
                orc = ORC.ShardedOracle(art, shards, cache_bytes=budget,
                                        gen_neighbors=gen)
            fn = orc.codes if name.startswith("serve_codes") else orc.lookup
            wall, hist = _closed_loop(fn, total, clients,
                                      batches_per_client, batch)
            s = dict(ORC.STATS)
            if s["resident_peak"] > budget:
                raise AssertionError(
                    f"{name}: resident cache bytes peaked at "
                    f"{s['resident_peak']} > budget {budget} — the LRU "
                    "contract is broken")
            nq = clients * batches_per_client * batch
            qps = nq / wall
            hm = s["hits"] + s["misses"]
            derived = (f"{qps:.3g} states/s  p50_us={hist.percentile(50):.3g}"
                       f" p99_us={hist.percentile(99):.3g}"
                       f" budget_pct={100 * budget / art_bytes:.0f}"
                       f" hit_rate={s['hits'] / max(hm, 1):.2f}"
                       f" evictions={s['evictions']}"
                       f" peak_bytes={s['resident_peak']}")
            rows.append((f"{name}_n{n}_c{clients}",
                         wall / (clients * batches_per_client) * 1e6,
                         derived))
            orc.close()

        # Tier J path: same packed words, ref-oracle gather (bit-for-bit
        # vs the pallas kernel by tests/test_kernels.py).
        import jax.numpy as jnp

        from repro.kernels import ops
        full = ORC.DistanceOracle(art, cache_bytes=1 << 30)
        raw = np.concatenate([full.cache.get(c)
                              for c in range(full.n_chunks)])
        full.close()
        pad = (-raw.size) % 4
        words = jnp.asarray(np.frombuffer(
            np.concatenate([raw, np.zeros(pad, np.uint8)]).tobytes(),
            dtype="<u4"))
        sample = np.random.default_rng(7).integers(
            0, total, batch).astype(np.int64)
        ops.bitpack_gather2(words, sample, impl="ref")  # compile/warm
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            ops.bitpack_gather2(words, sample, impl="ref")
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"serve_tierJ_gather_n{n}",
                     dt * 1e6,
                     f"{batch / dt:.3g} states/s  batch={batch} "
                     f"words={words.shape[0]}"))
    return rows


if __name__ == "__main__":
    for r in bench_serve():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
