"""Tier D streaming benchmarks: external sort / dedup / merge-difference
throughput with RAM held at O(chunk) — the disk-as-RAM claims of the paper,
measured on real files."""
from __future__ import annotations

import tempfile
import time
from typing import List, Tuple

import numpy as np

from repro.core.disk import DiskList


def bench_disk(n: int = 1 << 18, chunk_rows: int = 1 << 14
               ) -> List[Tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    with tempfile.TemporaryDirectory() as wd:
        data = rng.integers(0, n // 2, size=(n, 2)).astype(np.uint32)

        dl = DiskList(wd, width=2, chunk_rows=chunk_rows)
        t0 = time.perf_counter()
        dl.add(data)
        dl.store.flush()
        t_add = time.perf_counter() - t0
        rows.append(("disk_append_stream", t_add * 1e6,
                     f"{n*8/t_add/1e6:.3g} MB/s"))

        t0 = time.perf_counter()
        dl.remove_dupes(run_rows=chunk_rows * 2)
        t_dup = time.perf_counter() - t0
        rows.append(("disk_external_sort_dedup", t_dup * 1e6,
                     f"{n/t_dup:.3g} elt/s"))

        other = DiskList(wd, width=2, chunk_rows=chunk_rows)
        other.add(rng.integers(0, n // 2, size=(n // 4, 2)).astype(np.uint32))
        t0 = time.perf_counter()
        dl.remove_all(other, run_rows=chunk_rows * 2)
        t_diff = time.perf_counter() - t0
        rows.append(("disk_merge_difference", t_diff * 1e6,
                     f"{(n + n//4)/t_diff:.3g} elt/s"))

        t0 = time.perf_counter()
        tot = dl.reduce(lambda c: int(c[:, 0].astype(np.int64).sum()),
                        lambda a, b: a + b, 0)
        t_red = time.perf_counter() - t0
        rows.append(("disk_streaming_reduce", t_red * 1e6,
                     f"{dl.size()/t_red:.3g} elt/s"))
        dl.destroy(); other.destroy()
    return rows
