"""Microbenchmarks for the paper's §3 programming constructs (Tier J).

The paper has no numeric tables — its claims are the constructs themselves
— so the benchmark suite is one benchmark per construct, reporting
us_per_call and a derived throughput (elements/s), plus the Tier D (real
disk) twins where streaming I/O is the point.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import array as RA
from repro.core import constructs as C
from repro.core import hashtable as HT
from repro.core import rlist as RL


def timeit(fn: Callable, reps: int = 5) -> float:
    fn()                                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6   # us


def bench_constructs(n: int = 1 << 15) -> List[Tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    data2 = jax.random.randint(key, (n, 2), 0, n // 4).astype(jnp.uint32)
    rl = RL.from_rows(data2, capacity=2 * n)

    # map (vectorized user fn over every element)
    f_map = jax.jit(lambda l: RL.map_rows(l, lambda r: r[0] ^ r[1]))
    us = timeit(lambda: f_map(rl).block_until_ready())
    rows.append(("construct_map", us, f"{n/us*1e6:.3g} elt/s"))

    # reduce (sum of squares — the paper's example)
    f_red = jax.jit(lambda l: RL.reduce(
        l, lambda r: (r[0] * r[0]).astype(jnp.uint32),
        lambda a, b: a + b, jnp.uint32(0)))
    us = timeit(lambda: f_red(rl).block_until_ready())
    rows.append(("construct_reduce", us, f"{n/us*1e6:.3g} elt/s"))

    # removeDupes
    f_dup = jax.jit(RL.remove_dupes)
    us = timeit(lambda: f_dup(rl).count.block_until_ready())
    rows.append(("construct_removeDupes", us, f"{n/us*1e6:.3g} elt/s"))

    # set ops (union via addAll+removeDupes)
    other = RL.from_rows(
        jax.random.randint(jax.random.PRNGKey(1), (n, 2), 0,
                           n // 4).astype(jnp.uint32), capacity=2 * n)
    f_union = jax.jit(C.set_union)
    us = timeit(lambda: f_union(rl, other).count.block_until_ready())
    rows.append(("construct_set_union", us, f"{2*n/us*1e6:.3g} elt/s"))

    f_diff = jax.jit(C.set_difference)
    us = timeit(lambda: f_diff(rl, other).count.block_until_ready())
    rows.append(("construct_set_difference", us, f"{2*n/us*1e6:.3g} elt/s"))

    # native RoomySet (paper's planned primitive) vs the 3-temporary recipe
    from repro.core import rset as RS
    sa = RS.from_list(rl)
    sb = RS.from_list(other)
    f_int_recipe = jax.jit(C.set_intersection)
    us = timeit(lambda: f_int_recipe(rl, other).count.block_until_ready())
    rows.append(("set_intersection_recipe_3temp", us, f"{2*n/us*1e6:.3g} elt/s"))
    f_int_native = jax.jit(RS.intersection)
    us = timeit(lambda: f_int_native(sa, sb).count.block_until_ready())
    rows.append(("set_intersection_native_RoomySet", us,
                 f"{2*n/us*1e6:.3g} elt/s"))

    # chain reduction (delayed update + sync scatter-gather)
    a = jnp.arange(n, dtype=jnp.int32)
    ra = RA.make(a, queue_capacity=n, payload_dtype=jnp.int32)
    f_chain = jax.jit(lambda r: C.chain_reduce(r, lambda o, p: o + p))
    us = timeit(lambda: f_chain(ra).data.block_until_ready())
    rows.append(("construct_chain_reduction", us, f"{n/us*1e6:.3g} elt/s"))

    # parallel prefix (log-rounds of chain reduction)
    f_pp = jax.jit(lambda r: C.parallel_prefix(r, lambda o, p: o + p))
    us = timeit(lambda: f_pp(ra).data.block_until_ready())
    rows.append(("construct_parallel_prefix", us, f"{n/us*1e6:.3g} elt/s"))

    # pair reduction (blocked streaming over N² pairs; smaller N)
    m = 1 << 10
    rb = RA.make(jnp.arange(m, dtype=jnp.int32), queue_capacity=1)
    f_pair = jax.jit(lambda r: C.pair_reduce(
        r, lambda x, y: (x * y).astype(jnp.int32), lambda p, q: p + q,
        jnp.int32(0), block=128))
    us = timeit(lambda: f_pair(rb).block_until_ready())
    rows.append(("construct_pair_reduction", us,
                 f"{m*m/us*1e6:.3g} pair/s"))

    # hashtable sync (delayed inserts → sorted-merge batch)
    ht = HT.make(capacity=2 * n, key_width=1, queue_capacity=n,
                 val_dtype=jnp.int32)
    keys = jax.random.randint(key, (n, 1), 0, n).astype(jnp.uint32)
    vals = jnp.arange(n, dtype=jnp.int32)

    def ht_roundtrip():
        h, _ = HT.insert(ht, keys, vals)
        h, _ = HT.sync(h, combine=lambda a, b: a + b,
                       apply=lambda o, g, p: jnp.where(p, o + g, g))
        return h.count

    f_ht = jax.jit(ht_roundtrip)
    us = timeit(lambda: f_ht().block_until_ready())
    rows.append(("hashtable_insert_sync", us, f"{n/us*1e6:.3g} op/s"))

    # RoomyArray delayed-update sync (the bucket_scatter pattern)
    idx = jax.random.randint(key, (n,), 0, n).astype(jnp.int32)
    pay = jnp.ones((n,), jnp.int32)

    def ra_roundtrip():
        r, _ = RA.update(RA.make(a, n, payload_dtype=jnp.int32), idx, pay)
        return RA.sync(r, lambda p, q: p + q, lambda o, g: o + g).data

    f_ra = jax.jit(ra_roundtrip)
    us = timeit(lambda: f_ra().block_until_ready())
    rows.append(("array_update_sync", us, f"{n/us*1e6:.3g} op/s"))
    return rows
