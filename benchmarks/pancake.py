"""Pancake-sorting BFS — the paper's flagship application, three ways:

  tier J (device arrays), tier D (real out-of-core disk), and an in-RAM
  python set oracle. Level profiles must agree; the derived column reports
  states/s so the disk-streaming cost is visible (the paper's whole point
  is that this stays usable when RAM can't hold the frontier).
"""
from __future__ import annotations

import math
import tempfile
import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import constructs as C
from repro.core.disk import breadth_first_search as disk_bfs


def _gen_next_np(n: int):
    def gen(chunk: np.ndarray) -> np.ndarray:
        codes = chunk[:, 0]
        perms = np.stack([(codes >> (4 * i)) & 0xF for i in range(n)],
                         axis=1).astype(np.int64)
        outs = []
        for k in range(2, n + 1):
            flipped = np.concatenate([perms[:, :k][:, ::-1], perms[:, k:]],
                                     axis=1)
            code = np.zeros(chunk.shape[0], np.uint32)
            for i in range(n):
                code |= flipped[:, i].astype(np.uint32) << np.uint32(4 * i)
            outs.append(code)
        return np.concatenate(outs)[:, None]
    return gen


def _gen_next_jnp(n: int):
    def gen(row):
        code = row[0]
        perm = jnp.stack([(code >> jnp.uint32(4 * i)) & jnp.uint32(0xF)
                          for i in range(n)]).astype(jnp.int32)
        outs = []
        for k in range(2, n + 1):
            flipped = jnp.concatenate([perm[:k][::-1], perm[k:]])
            acc = jnp.uint32(0)
            for i in range(n):
                acc = acc | (flipped[i].astype(jnp.uint32)
                             << jnp.uint32(4 * i))
            outs.append(acc)
        return jnp.stack(outs)[:, None], jnp.ones((n - 1,), bool)
    return gen


def _start(n: int) -> np.uint32:
    return np.uint32(sum(i << (4 * i) for i in range(n)))


def oracle_levels(n: int) -> List[int]:
    cur = {tuple(range(n))}
    seen = set(cur)
    sizes = [1]
    while cur:
        nxt = set()
        for p in cur:
            for k in range(2, n + 1):
                q = p[:k][::-1] + p[k:]
                if q not in seen:
                    nxt.add(q)
        seen |= nxt
        if not nxt:
            break
        sizes.append(len(nxt))
        cur = nxt
    return sizes


def bench_pancake(n: int = 7) -> List[Tuple[str, float, str]]:
    rows = []
    total = math.factorial(n)

    t0 = time.perf_counter()
    want = oracle_levels(n)
    t_oracle = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = C.breadth_first_search(
        np.array([[_start(n)]], np.uint32), _gen_next_jnp(n),
        fanout=n - 1, width=1, all_capacity=total + 8,
        level_capacity=total + 8)
    t_j = time.perf_counter() - t0
    assert res.level_sizes == want, (res.level_sizes, want)

    with tempfile.TemporaryDirectory() as wd:
        t0 = time.perf_counter()
        sizes_d, all_lst = disk_bfs(wd, np.array([[_start(n)]], np.uint32),
                                    _gen_next_np(n), width=1,
                                    chunk_rows=1 << 12)
        t_d = time.perf_counter() - t0
        assert sizes_d == want, (sizes_d, want)
        all_lst.destroy()

    rows.append((f"bfs_pancake{n}_oracle", t_oracle * 1e6,
                 f"{total/t_oracle:.3g} states/s"))
    rows.append((f"bfs_pancake{n}_tierJ", t_j * 1e6,
                 f"{total/t_j:.3g} states/s diam={len(want)-1}"))
    rows.append((f"bfs_pancake{n}_tierD_disk", t_d * 1e6,
                 f"{total/t_d:.3g} states/s diam={len(want)-1}"))
    return rows
