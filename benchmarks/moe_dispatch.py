"""Roomy MoE dispatch vs einsum baseline — wall time on a host mesh and
the FLOP argument (the einsum path burns O(T·E·C·d) in one-hot matmuls;
the Roomy path doesn't). The production-scale collective comparison lives
in the dry-run (§Perf); this is the runnable small-scale twin.
"""
from __future__ import annotations

import subprocess
import sys
import time
from typing import List, Tuple


def bench_moe_dispatch() -> List[Tuple[str, float, str]]:
    # run in a subprocess with 8 fake devices so the Roomy path has a mesh
    code = """
import time, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.moe import init_moe, moe_einsum, moe_roomy
cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
    kernels="ref", dtype="float32", n_experts=8, top_k=2,
    d_model=128, d_ff=256, capacity_factor=2.0)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (16, 64, cfg.d_model))
f_e = jax.jit(lambda p, x: moe_einsum(p, x, cfg))
f_r = jax.jit(lambda p, x: moe_roomy(p, x, cfg, mesh))
for name, f in (("einsum", f_e), ("roomy", f_r)):
    f(p, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        f(p, x).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    print(f"RESULT {name} {us:.1f}")
"""
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    rows = []
    if proc.returncode != 0:
        return [("moe_dispatch_bench", 0.0,
                 f"FAILED: {proc.stderr[-200:]}")]
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT"):
            _, name, us = line.split()
            rows.append((f"moe_dispatch_{name}", float(us),
                         "tokens=1024 experts=8 top2 (8 fake devices)"))
    return rows
