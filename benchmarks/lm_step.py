"""Host-scale LM step benchmarks: train-step and decode-step wall time for
each family's smoke config (throughput sanity + regression tracking; the
production numbers are the dry-run roofline, not these)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, loss_fn, make_cache

FAMILIES = ["minicpm-2b", "phi3.5-moe-42b-a6.6b", "falcon-mamba-7b",
            "zamba2-1.2b", "gemma2-2b"]


def bench_lm_steps(b: int = 4, s: int = 64) -> List[Tuple[str, float, str]]:
    rows = []
    for arch in FAMILIES:
        cfg = get_config(arch, smoke=True).replace(kernels="ref")
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)),
                           jnp.int32)
        pos = jnp.tile(jnp.arange(s)[None], (b, 1))
        if cfg.mrope:
            pos = jnp.tile(pos[:, :, None], (1, 1, 3))
        inputs = {"positions": pos}
        if cfg.frontend_stub:
            inputs["embeds"] = jnp.asarray(
                rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
        else:
            inputs["tokens"] = toks[:, :s]
        batch = {"inputs": inputs, "labels": toks[:, 1:]}

        step = jax.jit(jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg)))
        step(params)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            step(params)[0].block_until_ready()
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"train_step_{arch}", us,
                     f"{b*s/us*1e6:.3g} tok/s (smoke cfg)"))

        caches = make_cache(cfg, b, max_len=s + 8)
        dec_in = {"positions": pos[:, :1]}
        if cfg.frontend_stub:
            dec_in["embeds"] = inputs["embeds"][:, :1]
        else:
            dec_in["tokens"] = toks[:, :1]
        dec = jax.jit(lambda p, i, c: decode_step(p, i, c, cfg))
        lg, caches2 = dec(params, dec_in, caches)
        lg.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            lg, _ = dec(params, dec_in, caches)
            lg.block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append((f"decode_step_{arch}", us,
                     f"{b/us*1e6:.3g} tok/s (smoke cfg)"))
    return rows
