"""Benchmark-regression gate: diff a fresh ``run.py --json`` record against
the committed baseline (``BENCH_baseline.json``).

Two families of signals, compared per benchmark row (matched by name):

  counters     every ``key=value`` token in the ``derived`` string
               (sorts/expansion, lexsorts/level, scatters/level,
               bytes/level, array_bytes/level, passes/level, ...).
               These are DETERMINISTIC pass/byte budgets — any increase
               beyond ``--counter-tol`` (default 2%, i.e. effectively
               exact for integer pass counts) fails the gate.  This is
               the teeth behind the ROADMAP's pass-budget contract: a PR
               that quietly re-adds a sort, scatter or array traversal
               per BFS level turns the job red.  ``speedup_vs_*`` tokens
               are ratios of two measured times and are skipped.

  throughput   the ``... states/s`` number of each row.  Wall-clock
               across machines is incomparable, so each row's
               fresh/baseline ratio is NORMALIZED by the median ratio of
               its row FAMILY (tierD / tierJ, parsed from the name): the
               two families are compile-bound vs I/O-bound, so a jax
               release that shifts compile times (or a runner with a
               different CPU-vs-disk balance) moves each family
               uniformly and cancels within it, while a single engine
               regressing relative to its siblings does not.  A row
               fails only when BOTH its normalized AND raw ratios fall
               below 1 - ``--threshold`` (default 25%): raw ≥ limit
               means the row did not actually get slower (it was flagged
               only because sibling rows got faster), raw < limit alone
               means the whole machine/family is slower (normalization
               vouches for the row).

Multiple fresh records may be passed (CI runs the preset twice): rows
merge per name keeping the BEST throughput sample.  Timing noise only
ever makes a run slower, so best-of over independent invocations
converges to the true floor and decorrelates the transient slow windows
(filesystem latency, CPU contention) that poison every repeat inside a
single invocation; the committed baseline is itself a best-of merge, so
the gate compares floor to floor.  Counters are deterministic, so they
are checked in EVERY fresh record — an increase in any sample fails,
regardless of which sample won the throughput merge.

Pure stdlib — the gate must run before (and regardless of) the jax
install.  Exit 0 = pass, 1 = regression, 2 = usage/schema error.

Updating the baseline (documented in .github/workflows/ci.yml): rerun
``python -m benchmarks.run --only bfs --pancake-n 5 --json fresh.json``
a couple of times, then ``python -m benchmarks.compare fresh1.json
fresh2.json BENCH_baseline.json --update-baseline`` (merges best-of)
and commit the result.
"""
from __future__ import annotations

import argparse
import json
import re
import statistics
import sys
from typing import Dict, Tuple

# "936 level states/s" / "39.3 states/s" — the row's throughput number.
_THROUGHPUT_RE = re.compile(r"([0-9.eE+-]+)\s+(?:level\s+)?states/s")
# "bytes/level=2.64e+03", "sorts/expansion=1.00", "lexsorts/level=1" ...
_COUNTER_RE = re.compile(r"([A-Za-z_][A-Za-z0-9_/]*)=([0-9.eE+-]+)(x?)")


def parse_derived(derived: str) -> Tuple[float, Dict[str, float]]:
    """Extract (throughput_or_None, {counter: value}) from a derived
    string.  ``speedup_vs_*`` ratio tokens (trailing 'x') are skipped —
    they compare two measured times and are not budgets."""
    m = _THROUGHPUT_RE.search(derived)
    throughput = float(m.group(1)) if m else None
    counters = {}
    for key, val, is_ratio in _COUNTER_RE.findall(derived):
        if is_ratio or key.startswith("speedup"):
            continue
        counters[key] = float(val)
    return throughput, counters


def _family(name: str) -> str:
    """Row family for normalization: tierD (I/O-bound) vs tierJ
    (compile/compute-bound) vs anything else."""
    for fam in ("tierD", "tierJ"):
        if fam in name:
            return fam
    return "other"


def load_rows(path: str, section: str = "bfs") -> Dict[str, str]:
    """{row_name: derived} for one section of a run.py --json record.

    The gate is scoped to a single section (default the CI preset's
    ``bfs``): a record that happens to carry other sections — e.g. an
    operator regenerating the baseline from a full ``run.py`` sweep —
    must not install rows the CI job never reruns, which would turn
    every subsequent run red with "rows missing"."""
    with open(path) as f:
        record = json.load(f)
    return {row["name"]: row["derived"]
            for row in record.get("sections", {}).get(section, [])}


def _better(derived_a: str, derived_b: str) -> str:
    """The sample to keep when merging: higher throughput wins (noise is
    one-sided — slow), throughput ties break toward lower counters.
    The merge feeds the throughput gate and --update-baseline only;
    counter budgets are checked against every record individually."""
    thr_a, cnt_a = parse_derived(derived_a)
    thr_b, cnt_b = parse_derived(derived_b)
    if (thr_a or 0) != (thr_b or 0):
        return derived_a if (thr_a or 0) > (thr_b or 0) else derived_b
    return derived_a if sum(cnt_a.values()) <= sum(cnt_b.values()) else derived_b


def load_merged(paths, section: str = "bfs") -> Dict[str, str]:
    """Best-of merge of several run.py --json records (per-row)."""
    merged: Dict[str, str] = {}
    for path in paths:
        for name, derived in load_rows(path, section).items():
            merged[name] = (_better(merged[name], derived)
                            if name in merged else derived)
    return merged


def compare(fresh_paths, base_path: str, threshold: float,
            counter_tol: float, section: str = "bfs") -> int:
    if isinstance(fresh_paths, str):
        fresh_paths = [fresh_paths]
    fresh_records = [(p, load_rows(p, section)) for p in fresh_paths]
    fresh = load_merged(fresh_paths, section)
    base = load_rows(base_path, section)
    if not base:
        print(f"FAIL: baseline {base_path} has no benchmark rows")
        return 2
    failures = []

    missing = sorted(set(base) - set(fresh))
    if missing:
        failures.append(f"rows missing from fresh run: {missing} "
                        "(dropped coverage fails the gate)")
    for name in sorted(set(fresh) - set(base)):
        print(f"NOTE: new row (not in baseline, unchecked): {name}")

    ratios = {}
    for name in sorted(set(base) & set(fresh)):
        b_thr, b_cnt = parse_derived(base[name])
        # Counters are deterministic: EVERY fresh sample must respect the
        # budget, not just the one that won the throughput merge.
        for key, bval in b_cnt.items():
            for path, rec in fresh_records:
                if name not in rec:
                    continue
                f_cnt = parse_derived(rec[name])[1]
                if key not in f_cnt:
                    failures.append(f"{name}: counter {key} disappeared "
                                    f"({path})")
                elif f_cnt[key] > bval * (1 + counter_tol) + 1e-12:
                    failures.append(
                        f"{name}: counter {key} increased "
                        f"{bval:g} -> {f_cnt[key]:g} (budget regression, "
                        f"{path})")
        f_thr = parse_derived(fresh[name])[0]
        if b_thr and f_thr:
            ratios[name] = f_thr / b_thr

    if ratios:
        # Per-family medians: tierD rows are I/O-bound, tierJ rows are
        # compile/compute-bound — they respond to machine differences
        # independently, so each family vouches only for its own.
        meds = {}
        for fam in {_family(n) for n in ratios}:
            fam_ratios = [r for n, r in ratios.items() if _family(n) == fam]
            meds[fam] = statistics.median(fam_ratios)
            print(f"machine-speed normalization [{fam}]: median throughput "
                  f"ratio {meds[fam]:.3f} over {len(fam_ratios)} rows")
        limit = 1 - threshold
        for name, r in sorted(ratios.items()):
            med = meds[_family(name)]
            norm = r / med if med > 0 else 0.0
            # Both must regress: raw >= limit ⇒ the row itself held up
            # (siblings merely got faster); norm >= limit ⇒ the whole
            # family/machine slowed uniformly, not this row.
            status = "ok"
            if norm < limit and r < limit:
                failures.append(
                    f"{name}: throughput {norm:.2f} of baseline normalized "
                    f"(raw {r:.2f}, limit {limit:.2f})")
                status = "REGRESSED"
            print(f"  {name}: raw {r:.2f} normalized {norm:.2f} [{status}]")

    if failures:
        print(f"\nFAIL: {len(failures)} benchmark regression(s):")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("\nOK: no pass/byte-counter increases, throughput within "
          f"{threshold:.0%} of baseline (normalized)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="+",
                    help="one or more fresh run.py --json outputs "
                         "(merged per-row, best throughput sample wins)")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max normalized throughput regression (0.25=25%%)")
    ap.add_argument("--counter-tol", type=float, default=0.02,
                    help="max relative counter increase (exact for ints)")
    ap.add_argument("--section", default="bfs",
                    help="benchmark section the gate covers (default: bfs, "
                         "the CI preset)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the (merged) fresh "
                         "record instead of comparing (commit the result)")
    args = ap.parse_args(argv)
    if args.update_baseline:
        merged = load_merged(args.fresh, args.section)
        if not merged:
            print(f"FAIL: refusing to install empty baseline from "
                  f"{args.fresh}")
            return 2
        # Always the merged, section-scoped form — a verbatim copy could
        # smuggle in other sections' rows or a non-empty errors map.
        with open(args.baseline, "w") as f:
            json.dump({"merged_from": list(args.fresh),
                       "sections": {args.section: [
                           {"name": n, "us_per_call": 0.0, "derived": d}
                           for n, d in sorted(merged.items())]},
                       "errors": {}}, f, indent=2)
        print(f"baseline updated: best-of {args.fresh} -> {args.baseline}")
        return 0
    return compare(args.fresh, args.baseline, args.threshold,
                   args.counter_tol, args.section)


if __name__ == "__main__":
    sys.exit(main())
