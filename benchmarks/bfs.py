"""BFS level-throughput benchmarks — the two engines' scoreboard.

Pancake (the paper's flagship app) and the S_n bubble-sort Cayley graph,
each on both tiers, fused vs unfused, plus **implicit vs sorted**:

  tier D   fused level pipeline (one sort pass streamed out of the
           expansion + LSM visited set) vs the literal removeDupes →
           removeAll → addAll composition, vs the implicit bit-array
           engine (rank-indexed 2-bit DiskBitArray, zero sorts)
  tier J   dedupe_subtract_fold (one lexsort/level) vs the 3-lexsort
           reference composition, vs constructs.implicit_bfs

Level throughput is the paper's cost model: the per-level *list/array
operations*, so the user generator's compute — identical across paths —
is timed separately and subtracted.  The derived column reports states/s
through the level pipeline plus the engine's unit of I/O cost:
sorts-per-level / lexsorts-per-level for the sorted engines, and **bytes
touched per level** for both (exact from bitarray.STATS on the implicit
side; rows-streamed × row-bytes on the sorted side) — the paper's
4·N/16-bytes-vs-frontier-size trade-off, recorded per PR.
"""
from __future__ import annotations

import math
import os
import sys
import tempfile
import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))

from repro.core import constructs as C
from repro.core import obs
from repro.core import ranking as R
from repro.core import rlist as RL
from repro.core.disk import ClusterConfig
from repro.core.disk import breadth_first_search as disk_bfs
from repro.core.disk import implicit_bfs as disk_implicit_bfs

from .pancake import _gen_next_jnp, _gen_next_np, _start, oracle_levels
from cayley_bfs import gen_next_jnp as cayley_gen_jnp
from cayley_bfs import gen_next_np as cayley_gen_np
from cayley_bfs import mahonian
from pancake_bits import neighbor_jnp as bits_neighbor_jnp
from pancake_bits import neighbors_np as bits_neighbors_np


def _best_of(repeats: int, fn) -> float:
    """Min wall time of ``fn()`` over ``repeats`` runs — timing noise is
    one-sided (slow), so the min converges to the true floor.  ``fn``
    must self-check its result; only the time comes back."""
    dt = 1e18
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        dt = min(dt, time.perf_counter() - t0)
    return dt


class _TimedGen:
    """Wraps a chunk generator, accumulating its own compute time so the
    benchmark can subtract it (it is identical in fused/unfused paths)."""

    def __init__(self, gen):
        self.gen = gen
        self.t = 0.0

    def __call__(self, chunk):
        t0 = time.perf_counter()
        out = self.gen(chunk)
        self.t += time.perf_counter() - t0
        return out


def _bench_disk(tag: str, gen_np, start: np.uint32, want: List[int],
                n_states: int, chunk_rows: int, fused: bool,
                repeats: int = 2):
    """Returns (row, best_level_time). Best-of-N to damp disk-cache noise."""
    levels = len(want) - 1
    best_wall, best_level = 1e18, 1e18
    es: dict = {}
    for _ in range(repeats):
        timed = _TimedGen(gen_np)
        with tempfile.TemporaryDirectory() as wd:
            # Per-repeat counter window: an obs.scope() delta instead of
            # the old global reset_stats(), which silently zeroed every
            # other observer's ledger (including a live trace summary)
            # between best-of repeats.
            with obs.scope() as sc:
                t0 = time.perf_counter()
                sizes, all_obj = disk_bfs(wd, np.array([[start]], np.uint32),
                                          timed, width=1,
                                          chunk_rows=chunk_rows, fused=fused)
                wall = time.perf_counter() - t0
                assert sizes == want, (tag, sizes, want)
                all_obj.destroy()
            es = sc.delta()["extsort"]
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
    # Per-expansion accounting: both paths run levels+1 expansions (the
    # last one discovers the empty frontier); the fused path additionally
    # pays one seed-sort pass, excluded here so the metric matches the
    # one-sort-per-level claim exactly (1.00 fused, 2.00 unfused).
    spe = (es["sort_passes"] - (1 if fused else 0)) / (levels + 1)
    name = f"bfs_{tag}_tierD_{'fused' if fused else 'unfused'}"
    row = (name, best_wall * 1e6,
           f"{n_states/best_level:.3g} level states/s "
           f"sorts/expansion={spe:.2f}")
    return row, best_level, es


def _bench_disk_sharded(tag: str, gen_np, start: np.uint32, want: List[int],
                        n_states: int, chunk_rows: int, shards: int,
                        repeats: int = 2, exchange=None):
    """Sorted-list engine through the sharded runtime (inline workers —
    the full bucket-exchange protocol without process-spawn noise, so the
    counters stay deterministic for the regression gate).  Derived
    reports sorts/expansion PER SHARD: the exchange must not add sort
    work (≤ 1.00, exactly the single-process budget on every shard that
    had a frontier).  ``exchange="pipelined"`` benches the overlapped
    produce/apply discipline against the default two-phase barrier —
    same per-shard sort budget by contract, the row exists to price the
    overlap."""
    levels = len(want) - 1
    best_wall, best_level = 1e18, 1e18
    es: dict = {}
    for _ in range(repeats):
        timed = _TimedGen(gen_np)
        with tempfile.TemporaryDirectory() as wd:
            with obs.scope() as sc:
                t0 = time.perf_counter()
                sizes, vis = disk_bfs(wd, np.array([[start]], np.uint32),
                                      timed, width=1, chunk_rows=chunk_rows,
                                      cluster=ClusterConfig(
                                          nshards=shards, mode="inline",
                                          exchange=exchange))
                wall = time.perf_counter() - t0
                assert sizes == want, (tag, sizes, want)
                vis.destroy()
            es = sc.delta()["extsort"]
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
    # One seed sort pass (the single seed row lands on one shard); every
    # other sort pass is a shard's per-level frontier sort.
    spe = (es["sort_passes"] - 1) / ((levels + 1) * shards)
    name = (f"bfs_{tag}_tierD_sharded{shards}"
            + ("_pipelined" if exchange == "pipelined" else ""))
    return (name, best_wall * 1e6,
            f"{n_states/best_level:.3g} level states/s "
            f"sorts/expansion={spe:.2f} rows_sorted="
            f"{es['rows_sorted']}")


def _bench_disk_implicit_sharded(n: int, want: List[int], n_total: int,
                                 chunk_elems: int, shards: int,
                                 repeats: int = 2, exchange=None):
    """Implicit engine through the sharded runtime (inline workers).
    passes/level is PER SHARD — the exchange must keep it at the fused
    budget of 1.00 + the seed pass amortized, in both the barrier and
    the pipelined (``exchange="pipelined"``) disciplines."""
    levels = len(want) - 1
    start_rank = int(R.rank_np(np.arange(n)[None, :])[0])
    best_wall, best_level = 1e18, 1e18
    arr_lvl = passes_lvl = 0.0
    for _ in range(repeats):
        timed = _TimedGen(bits_neighbors_np(n))
        with tempfile.TemporaryDirectory() as wd:
            with obs.scope() as sc:
                t0 = time.perf_counter()
                sizes, bits = disk_implicit_bfs(
                    wd, n_total, [start_rank], timed, chunk_elems=chunk_elems,
                    cluster=ClusterConfig(nshards=shards, mode="inline",
                                          exchange=exchange))
                wall = time.perf_counter() - t0
                assert sizes == want, (sizes, want)
                bits.destroy()
            bs = sc.delta()["bits"]
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
        arr_lvl = (bs["bytes_read"] + bs["bytes_written"]
                   - bs["log_bytes_read"]
                   - bs["log_bytes_written"]) / (levels + 1)
        passes_lvl = (bs["sync_passes"] + bs["scan_passes"]
                      ) / ((levels + 1) * shards)
    name = (f"bfs_pancake{n}_tierD_implicit_sharded{shards}"
            + ("_pipelined" if exchange == "pipelined" else ""))
    return (name, best_wall * 1e6,
            f"{n_total/best_level:.3g} level states/s "
            f"array_bytes/level={arr_lvl:.3g} "
            f"passes/level={passes_lvl:.2f} sorts/expansion=0.00")


def _bench_compression(n: int, want: List[int], start: np.uint32,
                       n_total: int, chunk_rows: int, repeats: int = 2
                       ) -> List[Tuple[str, float, str]]:
    """Compressed-run rows (docs/compression.md): both engines with
    ``compress=True``, reporting stored bytes per level and the
    raw/stored ratio from the codec ledger.  The pass budgets in these
    rows must equal the uncompressed fused rows' (codec I/O is booked
    separately, so sorts/expansion and passes/level are codec-blind).
    The rows are NOT in BENCH_baseline.json — compare.py surfaces them
    as unchecked NOTEs until an operator folds them in."""
    levels = len(want) - 1
    rows: List[Tuple[str, float, str]] = []

    # ------------------------------------------------ sorted, compressed
    best_wall, best_level = 1e18, 1e18
    es: dict = {}
    cd: dict = {}
    for _ in range(repeats):
        timed = _TimedGen(_gen_next_np(n))
        with tempfile.TemporaryDirectory() as wd:
            with obs.scope() as sc:
                t0 = time.perf_counter()
                sizes, vis = disk_bfs(wd, np.array([[start]], np.uint32),
                                      timed, width=1, chunk_rows=chunk_rows,
                                      compress=True)
                wall = time.perf_counter() - t0
                assert sizes == want, (sizes, want)
                vis.destroy()
            d = sc.delta()
            es, cd = d["extsort"], d.get("codec", {})
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
    spe = (es["sort_passes"] - 1) / (levels + 1)
    raw_b = cd.get("extsort_raw_bytes", 0)
    st_b = cd.get("extsort_stored_bytes", 0)
    ratio = raw_b / st_b if st_b else 0.0
    rows.append((f"bfs_pancake{n}_tierD_compressed", best_wall * 1e6,
                 f"{n_total/best_level:.3g} level states/s "
                 f"sorts/expansion={spe:.2f} "
                 f"stored_bytes/level={st_b/(levels+1):.3g} "
                 f"compress_ratio={ratio:.2f}x"))

    # ---------------------------------------------- implicit, compressed
    start_rank = int(R.rank_np(np.arange(n)[None, :])[0])
    best_wall, best_level = 1e18, 1e18
    bs: dict = {}
    for _ in range(repeats):
        timed = _TimedGen(bits_neighbors_np(n))
        with tempfile.TemporaryDirectory() as wd:
            with obs.scope() as sc:
                t0 = time.perf_counter()
                sizes, bits = disk_implicit_bfs(
                    wd, n_total, [start_rank], timed,
                    chunk_elems=chunk_rows * 4, compress=True)
                wall = time.perf_counter() - t0
                assert sizes == want, (sizes, want)
                bits.destroy()
            d = sc.delta()
            bs, cd = d["bits"], d.get("codec", {})
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
    passes_lvl = (bs["sync_passes"] + bs["scan_passes"]) / (levels + 1)
    raw_b = cd.get("bits_raw_bytes", 0) + cd.get("bits_raw_bytes_read", 0)
    st_b = (cd.get("bits_stored_bytes", 0)
            + cd.get("bits_stored_bytes_read", 0))
    ratio = raw_b / st_b if st_b else 0.0
    rows.append((f"bfs_pancake{n}_tierD_implicit_compressed", best_wall * 1e6,
                 f"{n_total/best_level:.3g} level states/s "
                 f"passes/level={passes_lvl:.2f} "
                 f"stored_bytes/level={st_b/(levels+1):.3g} "
                 f"compress_ratio={ratio:.2f}x sorts/expansion=0.00"))
    return rows


def _ops_per_level(fused: bool):
    """Exact (lexsort, scatter) op counts of one Tier J level, measured by
    tracing the un-jitted composition on a tiny input (the jitted driver
    reuses one trace across levels, so dividing the global counter by
    levels_run would understate the per-level op count).  The fused level
    folds the expansion-scatter staging into its lexsort, so it traces
    1 lexsort + 1 scatter; the reference composition traces 2 + 2."""
    all_small = RL.from_rows(jnp.array([[1]], jnp.uint32), capacity=4)
    nrows = jnp.array([[2], [3]], jnp.uint32)
    valid = jnp.ones((2,), bool)
    with obs.scope() as sc:
        if fused:
            C.dedupe_subtract_fold(nrows, valid, all_small, 4)
        else:
            nxt = RL.make(4, 1)
            nxt, _ = RL.add(nxt, nrows, valid)
            nxt = RL.remove_dupes(nxt)
            nxt = RL.remove_all(nxt, all_small)
            RL.add_all(all_small, nxt)
    tj = sc.delta()["tierj"]
    return tj["lexsorts"], tj["scatters"]


def _bench_disk_implicit(n: int, want: List[int], n_total: int,
                         chunk_elems: int, fused: bool = True,
                         repeats: int = 2):
    """Implicit (bit-array) Tier D engine: states/s through the level
    passes and exact bytes touched per level (bitarray.STATS).

    ``array_bytes/level`` isolates the packed-array traversals (total
    bytes minus the op-log subset): the fused planner pass reads the
    array ONCE per level where the unfused expand-then-sync composition
    reads it twice — the ~2x drop this row exists to record."""
    levels = len(want) - 1
    start_rank = int(R.rank_np(np.arange(n)[None, :])[0])
    best_wall, best_level = 1e18, 1e18
    bytes_lvl = arr_lvl = passes_lvl = 0.0
    for _ in range(repeats):
        timed = _TimedGen(bits_neighbors_np(n))
        with tempfile.TemporaryDirectory() as wd:
            with obs.scope() as sc:
                t0 = time.perf_counter()
                sizes, bits = disk_implicit_bfs(wd, n_total, [start_rank],
                                                timed,
                                                chunk_elems=chunk_elems,
                                                fused=fused)
                wall = time.perf_counter() - t0
                assert sizes == want, (sizes, want)
                bits.destroy()
            bs = sc.delta()["bits"]
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
        bytes_lvl = (bs["bytes_read"] + bs["bytes_written"]) / (levels + 1)
        arr_lvl = (bs["bytes_read"] + bs["bytes_written"]
                   - bs["log_bytes_read"]
                   - bs["log_bytes_written"]) / (levels + 1)
        passes_lvl = (bs["sync_passes"] + bs["scan_passes"]) / (levels + 1)
    name = (f"bfs_pancake{n}_tierD_implicit"
            + ("" if fused else "_unfused"))
    return ((name, best_wall * 1e6,
             f"{n_total/best_level:.3g} level states/s "
             f"bytes/level={bytes_lvl:.3g} array_bytes/level={arr_lvl:.3g} "
             f"passes/level={passes_lvl:.2f} sorts/expansion=0.00"),
            best_level)


def bench_bfs(n: int = 7, chunk_rows: int = 1 << 14, shards: int = 0,
              compress: bool = False) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    # ---------------------------------------------------------- pancake
    total = math.factorial(n)
    want = oracle_levels(n)
    start = _start(n)
    levels = len(want) - 1
    # Small presets (the CI gate runs n=5) have sub-100ms level times, so
    # best-of-2 is noise-bound; more repeats converge the min to the true
    # floor (noise only ever ADDS time) and keep the regression gate quiet.
    repeats = 10 if n <= 5 else 2

    fused_row, t_f, es_f = _bench_disk(f"pancake{n}", _gen_next_np(n), start,
                                       want, total, chunk_rows, fused=True,
                                       repeats=repeats)
    # Bytes touched per level by the sorted engine: rows streamed through
    # sort passes plus visited-set chunks probed, at 4·width bytes/row
    # (the last repeat's scope delta — representative, the runs are
    # identical). The implicit row reports its exact analogue.
    sorted_bytes_lvl = 4 * (es_f["rows_sorted"]
                            + es_f["chunks_probed"] * chunk_rows
                            ) / (levels + 1)
    unfused_row, t_u, _ = _bench_disk(f"pancake{n}", _gen_next_np(n), start,
                                      want, total, chunk_rows, fused=False,
                                      repeats=repeats)
    rows.append((fused_row[0], fused_row[1],
                 fused_row[2] + f" bytes/level={sorted_bytes_lvl:.3g}"
                 f" speedup_vs_unfused={t_u/t_f:.2f}x"))
    rows.append(unfused_row)

    # ------------------------------------- implicit vs sorted (tier D)
    imp_row, t_i = _bench_disk_implicit(n, want, total,
                                        chunk_elems=chunk_rows * 4,
                                        repeats=repeats)
    rows.append((imp_row[0], imp_row[1],
                 imp_row[2] + f" speedup_vs_sorted={t_f/t_i:.2f}x"))
    imp_u_row, t_iu = _bench_disk_implicit(n, want, total,
                                           chunk_elems=chunk_rows * 4,
                                           fused=False, repeats=repeats)
    rows.append((imp_u_row[0], imp_u_row[1],
                 imp_u_row[2] + f" speedup_vs_fused={t_i/t_iu:.2f}x"))

    # ------------------------------------ compressed runs (NOTE rows)
    if compress:
        rows.extend(_bench_compression(n, want, start, total, chunk_rows,
                                       repeats=repeats))

    # ----------------------------------------- sharded runtime (tier D)
    if shards >= 2:
        # Barrier (default) and pipelined exchange rows side by side: the
        # per-shard sort/pass budgets must be identical (gated counters);
        # the throughput delta prices the produce/apply overlap.
        for exchange in (None, "pipelined"):
            rows.append(_bench_disk_sharded(f"pancake{n}", _gen_next_np(n),
                                            start, want, total, chunk_rows,
                                            shards, repeats=repeats,
                                            exchange=exchange))
            rows.append(_bench_disk_implicit_sharded(
                n, want, total, chunk_elems=chunk_rows * 4, shards=shards,
                repeats=repeats, exchange=exchange))

    # Tier J rows are compile-dominated at small n (each repeat re-traces,
    # so every sample measures the same compile+run quantity); best-of-N
    # damps the transient slow windows the regression gate must not see.
    repeats_j = 3 if n <= 5 else 1

    for fused in (True, False):
        def run_sorted(fused=fused):
            res = C.breadth_first_search(
                np.array([[start]], np.uint32), _gen_next_jnp(n),
                fanout=n - 1, width=1, all_capacity=total + 8,
                level_capacity=total + 8, fused=fused)
            assert res.level_sizes == want
        dt = _best_of(repeats_j, run_sorted)
        spl, scl = _ops_per_level(fused)
        rows.append((f"bfs_pancake{n}_tierJ_{'fused' if fused else 'unfused'}",
                     dt * 1e6,
                     f"{total/dt:.3g} states/s lexsorts/level={spl} "
                     f"scatters/level={scl}"))

    for fused in (True, False):
        nbytes = 4 * ((total + 15) // 16)     # uint32 words, 16 elems each

        def run_implicit(fused=fused):
            sizes, _bits = C.implicit_bfs(total, [int(R.rank_np(
                np.arange(n)[None, :])[0])], bits_neighbor_jnp(n),
                fused=fused)
            assert sizes == want
        dt = _best_of(repeats_j, run_implicit)
        # Bytes touched per level: fused runs ONE kernel over the packed
        # array (read + write = 2·nbytes); the unfused reference runs the
        # mark scatter and the rotate LUT as separate kernels (4·nbytes).
        per_level = (2 if fused else 4) * nbytes
        name = f"bfs_pancake{n}_tierJ_implicit" + ("" if fused
                                                   else "_unfused")
        rows.append((name, dt * 1e6,
                     f"{total/dt:.3g} states/s lexsorts/level=0 "
                     f"bytes/level={per_level:.3g}"))

    # ----------------------------------------------------------- cayley
    cn = max(5, n - 1)
    ctotal = math.factorial(cn)
    cwant = mahonian(cn)
    cstart = np.uint32(sum(i << (4 * i) for i in range(cn)))

    crepeats = 10 if cn <= 5 else 2
    crepeats_j = 3 if cn <= 5 else 1
    crow, _, _ = _bench_disk(f"cayley{cn}", cayley_gen_np(cn), cstart, cwant,
                             ctotal, chunk_rows, fused=True, repeats=crepeats)
    rows.append(crow)

    def run_cayley_j():
        res = C.breadth_first_search(
            np.array([[cstart]], np.uint32), cayley_gen_jnp(cn),
            fanout=cn - 1, width=1, all_capacity=ctotal + 8,
            level_capacity=ctotal + 8)
        assert res.level_sizes == cwant
    dt = _best_of(crepeats_j, run_cayley_j)
    rows.append((f"bfs_cayley{cn}_tierJ_fused", dt * 1e6,
                 f"{ctotal/dt:.3g} states/s"))
    return rows
