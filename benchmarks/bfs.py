"""BFS level-throughput benchmarks — the two engines' scoreboard.

Pancake (the paper's flagship app) and the S_n bubble-sort Cayley graph,
each on both tiers, fused vs unfused, plus **implicit vs sorted**:

  tier D   fused level pipeline (one sort pass streamed out of the
           expansion + LSM visited set) vs the literal removeDupes →
           removeAll → addAll composition, vs the implicit bit-array
           engine (rank-indexed 2-bit DiskBitArray, zero sorts)
  tier J   dedupe_subtract_fold (one lexsort/level) vs the 3-lexsort
           reference composition, vs constructs.implicit_bfs

Level throughput is the paper's cost model: the per-level *list/array
operations*, so the user generator's compute — identical across paths —
is timed separately and subtracted.  The derived column reports states/s
through the level pipeline plus the engine's unit of I/O cost:
sorts-per-level / lexsorts-per-level for the sorted engines, and **bytes
touched per level** for both (exact from bitarray.STATS on the implicit
side; rows-streamed × row-bytes on the sorted side) — the paper's
4·N/16-bytes-vs-frontier-size trade-off, recorded per PR.
"""
from __future__ import annotations

import math
import os
import sys
import tempfile
import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

sys.path.append(os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "examples"))

from repro.core import constructs as C
from repro.core import ranking as R
from repro.core import rlist as RL
from repro.core import types as T
from repro.core.disk import bitarray as DBA
from repro.core.disk import breadth_first_search as disk_bfs
from repro.core.disk import extsort
from repro.core.disk import implicit_bfs as disk_implicit_bfs

from .pancake import _gen_next_jnp, _gen_next_np, _start, oracle_levels
from cayley_bfs import gen_next_jnp as cayley_gen_jnp
from cayley_bfs import gen_next_np as cayley_gen_np
from cayley_bfs import mahonian
from pancake_bits import neighbor_jnp as bits_neighbor_jnp
from pancake_bits import neighbors_np as bits_neighbors_np


class _TimedGen:
    """Wraps a chunk generator, accumulating its own compute time so the
    benchmark can subtract it (it is identical in fused/unfused paths)."""

    def __init__(self, gen):
        self.gen = gen
        self.t = 0.0

    def __call__(self, chunk):
        t0 = time.perf_counter()
        out = self.gen(chunk)
        self.t += time.perf_counter() - t0
        return out


def _bench_disk(tag: str, gen_np, start: np.uint32, want: List[int],
                n_states: int, chunk_rows: int, fused: bool,
                repeats: int = 2):
    """Returns (row, best_level_time). Best-of-N to damp disk-cache noise."""
    levels = len(want) - 1
    best_wall, best_level = 1e18, 1e18
    for _ in range(repeats):
        timed = _TimedGen(gen_np)
        with tempfile.TemporaryDirectory() as wd:
            extsort.reset_stats()
            t0 = time.perf_counter()
            sizes, all_obj = disk_bfs(wd, np.array([[start]], np.uint32),
                                      timed, width=1, chunk_rows=chunk_rows,
                                      fused=fused)
            wall = time.perf_counter() - t0
            assert sizes == want, (tag, sizes, want)
            all_obj.destroy()
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
    # Per-expansion accounting: both paths run levels+1 expansions (the
    # last one discovers the empty frontier); the fused path additionally
    # pays one seed-sort pass, excluded here so the metric matches the
    # one-sort-per-level claim exactly (1.00 fused, 2.00 unfused).
    spe = ((extsort.STATS["sort_passes"] - (1 if fused else 0))
           / (levels + 1))
    name = f"bfs_{tag}_tierD_{'fused' if fused else 'unfused'}"
    row = (name, best_wall * 1e6,
           f"{n_states/best_level:.3g} level states/s "
           f"sorts/expansion={spe:.2f}")
    return row, best_level


def _lexsorts_per_level(fused: bool) -> int:
    """Exact lexsort op count of one Tier J level, measured by tracing the
    un-jitted composition on a tiny input (the jitted driver reuses one
    trace across levels, so dividing the global counter by levels_run
    would understate the per-level op count)."""
    all_small = RL.from_rows(jnp.array([[1]], jnp.uint32), capacity=4)
    nrows = jnp.array([[2], [3]], jnp.uint32)
    valid = jnp.ones((2,), bool)
    T.reset_sort_stats()
    if fused:
        C.dedupe_subtract_fold(nrows, valid, all_small, 4)
    else:
        nxt = RL.make(4, 1)
        nxt, _ = RL.add(nxt, nrows, valid)
        nxt = RL.remove_dupes(nxt)
        nxt = RL.remove_all(nxt, all_small)
        RL.add_all(all_small, nxt)
    return T.SORT_STATS["lexsorts"]


def _bench_disk_implicit(n: int, want: List[int], n_total: int,
                         chunk_elems: int, repeats: int = 2):
    """Implicit (bit-array) Tier D engine: states/s through the level
    passes and exact bytes touched per level (bitarray.STATS)."""
    levels = len(want) - 1
    start_rank = int(R.rank_np(np.arange(n)[None, :])[0])
    best_wall, best_level, bytes_lvl = 1e18, 1e18, 0.0
    for _ in range(repeats):
        timed = _TimedGen(bits_neighbors_np(n))
        with tempfile.TemporaryDirectory() as wd:
            DBA.reset_stats()
            t0 = time.perf_counter()
            sizes, bits = disk_implicit_bfs(wd, n_total, [start_rank], timed,
                                            chunk_elems=chunk_elems)
            wall = time.perf_counter() - t0
            assert sizes == want, (sizes, want)
            bits.destroy()
        best_wall = min(best_wall, wall)
        best_level = min(best_level, wall - timed.t)
        bytes_lvl = (DBA.STATS["bytes_read"]
                     + DBA.STATS["bytes_written"]) / (levels + 1)
    return ((f"bfs_pancake{n}_tierD_implicit", best_wall * 1e6,
             f"{n_total/best_level:.3g} level states/s "
             f"bytes/level={bytes_lvl:.3g} sorts/expansion=0.00"),
            best_level)


def bench_bfs(n: int = 7, chunk_rows: int = 1 << 14
              ) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    # ---------------------------------------------------------- pancake
    total = math.factorial(n)
    want = oracle_levels(n)
    start = _start(n)
    levels = len(want) - 1

    fused_row, t_f = _bench_disk(f"pancake{n}", _gen_next_np(n), start, want,
                                 total, chunk_rows, fused=True)
    # Bytes touched per level by the sorted engine: rows streamed through
    # sort passes plus visited-set chunks probed, at 4·width bytes/row
    # (STATS reflect the last repeat — representative, the runs are
    # identical). The implicit row reports its exact analogue.
    sorted_bytes_lvl = 4 * (extsort.STATS["rows_sorted"]
                            + extsort.STATS["chunks_probed"] * chunk_rows
                            ) / (levels + 1)
    unfused_row, t_u = _bench_disk(f"pancake{n}", _gen_next_np(n), start,
                                   want, total, chunk_rows, fused=False)
    rows.append((fused_row[0], fused_row[1],
                 fused_row[2] + f" bytes/level={sorted_bytes_lvl:.3g}"
                 f" speedup_vs_unfused={t_u/t_f:.2f}x"))
    rows.append(unfused_row)

    # ------------------------------------- implicit vs sorted (tier D)
    imp_row, t_i = _bench_disk_implicit(n, want, total,
                                        chunk_elems=chunk_rows * 4)
    rows.append((imp_row[0], imp_row[1],
                 imp_row[2] + f" speedup_vs_sorted={t_f/t_i:.2f}x"))

    for fused in (True, False):
        t0 = time.perf_counter()
        res = C.breadth_first_search(
            np.array([[start]], np.uint32), _gen_next_jnp(n), fanout=n - 1,
            width=1, all_capacity=total + 8, level_capacity=total + 8,
            fused=fused)
        dt = time.perf_counter() - t0
        assert res.level_sizes == want
        spl = _lexsorts_per_level(fused)
        rows.append((f"bfs_pancake{n}_tierJ_{'fused' if fused else 'unfused'}",
                     dt * 1e6,
                     f"{total/dt:.3g} states/s lexsorts/level={spl}"))

    t0 = time.perf_counter()
    sizes, bits = C.implicit_bfs(total, [int(R.rank_np(
        np.arange(n)[None, :])[0])], bits_neighbor_jnp(n))
    dt = time.perf_counter() - t0
    assert sizes == want
    # Bytes touched per level: the packed array read+written once per level
    # (mark pass + rotate pass), n/8 bytes each way.
    rows.append((f"bfs_pancake{n}_tierJ_implicit", dt * 1e6,
                 f"{total/dt:.3g} states/s lexsorts/level=0 "
                 f"bytes/level={2 * bits.data.nbytes:.3g}"))

    # ----------------------------------------------------------- cayley
    cn = max(5, n - 1)
    ctotal = math.factorial(cn)
    cwant = mahonian(cn)
    cstart = np.uint32(sum(i << (4 * i) for i in range(cn)))

    crow, _ = _bench_disk(f"cayley{cn}", cayley_gen_np(cn), cstart, cwant,
                          ctotal, chunk_rows, fused=True)
    rows.append(crow)
    t0 = time.perf_counter()
    res = C.breadth_first_search(
        np.array([[cstart]], np.uint32), cayley_gen_jnp(cn), fanout=cn - 1,
        width=1, all_capacity=ctotal + 8, level_capacity=ctotal + 8)
    dt = time.perf_counter() - t0
    assert res.level_sizes == cwant
    rows.append((f"bfs_cayley{cn}_tierJ_fused", dt * 1e6,
                 f"{ctotal/dt:.3g} states/s"))
    return rows
